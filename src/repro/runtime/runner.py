"""AsyncRunner: per-arrival training on the flat engine state.

The production counterpart of the event-driven simulator: the same arrival
semantics (``runtime/loop.py``) driving the paper's fully-asynchronous
server iteration on the canonical ``FlatTrainState`` — per arrival, one
``DuDeEngine.commit`` (or an ``AsyncAlgo`` rule from ``core/algos.py``) plus
the flat optimizer apply, compiled as ONE jitted device step that is
elementwise on the P-axis-sharded ``[P]`` slabs (mesh-native engines commit
under their ``shard_map``, so a sharded arrival step moves zero bytes).

Differences from the simulator, by design:

* math runs on flat slabs (identical values: flat and pytree applies agree
  bit-for-bit on f32 params, so a runner replaying a simulator trace
  reproduces its parameters exactly — ``tests/test_runtime.py``);
* the host never blocks per arrival: device steps are pushed through a
  bounded ``DeviceQueue`` (depth 2 = double buffering) that only waits when
  the device is ``queue_depth`` full steps behind the scheduler, and the
  loss EMA stays on device between record points;
* worker model snapshots are flat ``[P]`` vectors (n of them — the price of
  physical staleness), handed out by the loop's ``deliver`` hook.  The
  arrival step therefore does NOT donate its state: the freshest snapshot
  aliases ``state.params``.  Under a compressed ``commit_format`` the n
  snapshots are delta-encoded (tiled int8, ``core/compression.py``) against
  the run-start master instead of stored as full copies — ~3.9x less
  snapshot memory; commits themselves are compressed inside
  ``DuDeEngine.commit`` (int8 payload + per-tile scales + EF residual).

The per-arrival math lives in ``_RunSession`` — one object exposing the
``on_arrival`` / ``deliver`` callbacks ``drive_arrivals`` wants, plus the
``commit`` / ``snapshot_arrays`` halves the multi-host ``HostRunner``
(``runtime/hostloop.py``) drives off socket readiness — so the simulated
and the distributed run execute the IDENTICAL commit/apply/record path and
a recorded multi-host trace replays bit-for-bit through ``run()``.

Two gradient keying modes (``key_mode``):

* ``"arrival"`` (default, historical) — one global PRNG key split per
  arrival and one shared sampling rng, consumed in arrival order.  Only a
  simulator can do this: the key a gradient uses depends on WHEN it will
  arrive.
* ``"worker"`` — dispatch-deterministic: job ``j`` of worker ``w`` uses
  ``fold_in(fold_in(key(seed), w), j)`` and a per-worker
  ``np.random.SeedSequence([seed, w])`` sampling stream
  (:func:`worker_rng`).  A physically distributed worker can compute this
  WITHOUT knowing the global arrival order, so multi-host runs use it — and
  a replay with the same mode reproduces every gradient bitwise.

Documented in docs/async.md ("The AsyncRunner" / "In-flight depth and the
device queue" / "Multi-host transport").
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.algos import AsyncAlgo, make_async_algo
from ..core.compression import commit_digest
from ..core.engine import DuDeEngine
from ..optim import FlatOptState, FlatTrainState, flat_twin
from .arrivals import ArrivalProcess, ArrivalTrace
from .loop import LoopStats, drive_arrivals

Pytree = Any

__all__ = ["AsyncResult", "DeviceQueue", "AsyncRunner", "KEY_MODES",
           "worker_rng", "worker_key"]

KEY_MODES = ("arrival", "worker")


def worker_rng(seed: int, worker: int) -> np.random.Generator:
    """The per-worker sampling stream of ``key_mode="worker"`` runs — one
    ``SeedSequence([seed, worker])`` generator per worker, constructible
    identically on the server (replay) and on a remote worker process."""
    return np.random.default_rng(np.random.SeedSequence([seed, worker]))


def worker_key(seed: int, worker: int, job: int) -> jax.Array:
    """The gradient PRNG key of worker ``worker``'s ``job``-th dispatch
    under ``key_mode="worker"`` — pure fold_ins, no global split order."""
    return jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(seed), worker), job)


class DeviceQueue:
    """Bounded queue of in-flight device computations.

    ``push(x)`` enqueues a device value the host does not need yet; once
    more than ``depth`` values are outstanding the oldest is waited on —
    so the host runs at most ``depth`` steps ahead of the device (depth 2 =
    classic double buffering: one step executing, one queued behind it)
    while never synchronizing when a buffer slot is free.
    """

    def __init__(self, depth: int = 2):
        if depth < 1:
            raise ValueError(f"queue depth {depth} must be >= 1")
        self.depth = depth
        self._q: collections.deque = collections.deque()
        self.waits = 0  # times the host actually blocked (for tests/bench)

    def push(self, value) -> None:
        self._q.append(value)
        if len(self._q) > self.depth:
            self.waits += 1
            jax.block_until_ready(self._q.popleft())

    def flush(self) -> None:
        while self._q:
            jax.block_until_ready(self._q.popleft())

    def __len__(self) -> int:
        return len(self._q)


@dataclasses.dataclass
class AsyncResult:
    """One AsyncRunner run, mirror of the simulator's ``SimResult`` plus the
    loop's scheduling stats and the recorded trace."""

    name: str
    times: np.ndarray        # simulated clock at each record point
    iters: np.ndarray        # server iterations at each record point
    losses: np.ndarray       # running train-loss EMA (or eval_fn) at records
    gnorms: np.ndarray       # |g| at each record point
    state: FlatTrainState    # final train state (flat)
    tau_max: int
    n_grads: int             # stochastic gradients computed
    stats: LoopStats
    # sparse commit transport (engines with sparse_meta): SparseRow commits
    # shipped host->device.  ``wire_bytes`` counts the FRAMED bytes a socket
    # would carry (prefix + header + manifest + padding — runtime/transport
    # framing; on multi-host runs, the bytes it actually carried);
    # ``payload_bytes`` the analytic array payload alone (0 on dense runs).
    wire_rows: int = 0
    wire_bytes: int = 0
    payload_bytes: int = 0
    # snapshot-encode cache: encodes actually run vs deliveries served from
    # the cache because params were unchanged since the last delivery
    snap_encodes: int = 0
    snap_reuses: int = 0
    # per-arrival commit digests (record_digests runs / multi-host runs)
    digests: Optional[tuple] = None
    # multi-host robustness counters (HostRunner runs; 0 on simulated runs)
    dropouts: int = 0
    reconnects: int = 0
    dropped_workers: tuple = ()
    # server-end socket byte totals of a hosted run (all frames: handshakes,
    # snapshots, commits, heartbeats), summed over every link ever attached
    wire_sent: int = 0
    wire_recv: int = 0

    @property
    def trace(self) -> ArrivalTrace:
        return self.stats.trace


class _RunSession:
    """The per-arrival math of ONE run, factored out of the event source.

    ``drive_arrivals`` consumes ``on_arrival`` / ``deliver``; the multi-host
    ``HostRunner`` calls ``commit`` (with a remotely computed gradient) and
    ``snapshot_arrays`` (the delta encoding a delivery ships) — all four run
    the same jits, counters and record points, so a simulated run, a hosted
    run, and a trace replay share one code path.
    """

    def __init__(self, runner: "AsyncRunner", state: FlatTrainState,
                 sample_fn: Optional[Callable], *, seed: int,
                 record_every: int, eval_fn: Optional[Callable], ema: float,
                 key_mode: str, record_digests: bool):
        if key_mode not in KEY_MODES:
            raise ValueError(
                f"unknown key_mode {key_mode!r}; options: {KEY_MODES}")
        r = self.r = runner
        n = runner.engine.n_workers
        self.sample_fn = sample_fn
        self.seed = seed
        self.record_every = record_every
        self.eval_fn = eval_fn
        self.ema = ema
        self.key_mode = key_mode
        self.state = state
        self.key = jax.random.PRNGKey(seed)
        self.rng = np.random.default_rng(seed)  # routing + "arrival" sampling
        self.rngs = ([worker_rng(seed, w) for w in range(n)]
                     if key_mode == "worker" else None)
        if key_mode == "worker" and r.algo.route is not None:
            raise ValueError(
                f"key_mode='worker' needs the greedy route (algo "
                f"{r.algo.name!r} routes {r.algo.route!r}): routed "
                "deliveries draw from a shared rng no remote worker can see")
        self.queue = DeviceQueue(r.queue_depth)
        self.running = None
        self.n_grads = 0
        self.wire_rows = 0
        self.wire_bytes = 0
        self.payload_bytes = 0
        self.snap_encodes = 0
        self.snap_reuses = 0
        self.arrived = [0] * n   # per-worker collected jobs (job id source)
        self.digests: Optional[list] = [] if record_digests else None
        self.times: list = []
        self.iters: list = []
        self.losses: list = []
        self.gnorms: list = []
        # deliver() cache: the params object the last snapshot encode ran
        # on, and its encoding.  Identity (`is`) comparison — the arrival
        # step returns a NEW params array whenever anything committed, so an
        # unchanged object means an unchanged snapshot; a delivery between
        # two commits (or before the first) reuses the last encode instead
        # of re-running it.  The object itself is held (not id()) so a GC'd
        # array can never alias a stale id.
        self._snap_cache = {"params": None, "enc": None}
        # every worker starts on the initial model (version 0)
        if r._compressed:
            # delta-encoded snapshots against the run-start master; the
            # zero delta (q=0 decodes to exactly 0) is ONE encode delivered
            # n ways — the first n cache reuses
            self.base = state.params
            zero_delta = r._snap_encode(self.base, self.base)
            self.snap_encodes = 1
            self.snap_reuses = n - 1
            self._snap_cache.update(params=self.base, enc=zero_delta)
            self.worker_snaps = [zero_delta for _ in range(n)]
            self.worker_params = None
        else:
            self.base = None
            self.worker_snaps = None
            self.worker_params = [state.params for _ in range(n)]
        if r._sparse:
            from .transport import (commit_frame_nbytes, pack_arrays,
                                    sparse_row_arrays)
            # the framed size of a commit depends only on (worker, job) ids
            # and the static SparseRow manifest — build the manifest once
            # from the row layout so per-arrival accounting never syncs the
            # device (and matches pack_arrays on a real row byte-for-byte)
            cap, k = r.engine.cap_tiles, r.engine.codec.topk
            self._row_manifest, _ = pack_arrays([
                np.zeros((cap,), np.int32), np.zeros((cap, k), np.uint8),
                np.zeros((cap, k), np.int8), np.zeros((cap,), np.float32),
                np.zeros((), np.int32)])
            self._commit_frame_nbytes = commit_frame_nbytes
            self._sparse_row_arrays = sparse_row_arrays

    # ------------------------------------------------------------ snapshots

    def worker_model(self, w: int) -> Pytree:
        r = self.r
        if r._sparse:
            return r._snap_unravel(self.base, self.worker_snaps[w])
        if r._compressed:
            q, s = self.worker_snaps[w]
            return r._snap_unravel(self.base, q, s)
        return r._unravel(self.worker_params[w])

    def deliver(self, worker: int) -> None:
        if self.r._compressed:
            params = self.state.params
            if self._snap_cache["params"] is not params:
                self._snap_cache["params"] = params
                self._snap_cache["enc"] = self.r._snap_encode(params,
                                                              self.base)
                self.snap_encodes += 1
            else:
                self.snap_reuses += 1
            self.worker_snaps[worker] = self._snap_cache["enc"]
        else:
            self.worker_params[worker] = self.state.params

    def snapshot_arrays(self, worker: int) -> tuple:
        """The host-side arrays a delivery ships on the wire: the full f32
        params (uncompressed formats) or the delta encoding vs the run-start
        base — EXACTLY what ``worker_model`` would decode, so a remote
        worker running the same ``_snap_unravel`` jit sees the same bits.
        Materializes to numpy (a send must); call after ``deliver``."""
        r = self.r
        if r._sparse:
            return self._sparse_row_arrays(self.worker_snaps[worker])
        if r._compressed:
            q, s = self.worker_snaps[worker]
            return (np.asarray(q), np.asarray(s))
        return (np.asarray(self.worker_params[worker]),)

    # -------------------------------------------------------------- commits

    def grad_for(self, view) -> tuple:
        """Local gradient compute (single-process path): the arriving
        worker's ``(loss, gflat)`` on the snapshot it holds, keyed per
        ``key_mode``."""
        w = view.worker
        if self.key_mode == "worker":
            k1 = worker_key(self.seed, w, self.arrived[w])
            batch = self.sample_fn(w, self.rngs[w])
        else:
            self.key, k1 = jax.random.split(self.key)
            batch = self.sample_fn(w, self.rng)
        loss, g = self.r._grad(self.worker_model(w), batch, k1)
        return loss, self.r._ravel(g)

    def commit(self, view, loss, gflat) -> bool:
        """One server iteration from an arrived gradient: encode/fold (or
        dense commit) + flat apply + EMA/record bookkeeping.  ``loss`` and
        ``gflat`` may be device values (local compute) or host arrays (a
        frame's payload) — the math is the same jit either way.  A partial
        arrival (client-state ``view.completeness`` < 1) scales the flat
        gradient BEFORE digesting/committing — the scale is an exact f32
        constant from the trace, and an elementwise f32 multiply commutes
        with ravel, so the simulator's pytree-side scaling stays bitwise
        identical."""
        r = self.r
        w = int(view.worker)
        job = self.arrived[w]
        self.arrived[w] = job + 1
        self.n_grads += 1
        gflat = jnp.asarray(gflat)
        if view.completeness != 1.0:
            gflat = jnp.float32(view.completeness) * gflat
        if self.digests is not None:
            self.digests.append(commit_digest(np.asarray(gflat)))
        if r._sparse:
            st = self.state
            srv, wire = r._encode(st.engine, jnp.int32(w), gflat)
            self.wire_rows += 1
            nbytes = r._wire_nbytes(wire)
            self.payload_bytes += nbytes
            self.wire_bytes += self._commit_frame_nbytes(
                w, job, self._row_manifest, nbytes)
            self.state, g_dir = r._step_sparse(
                FlatTrainState(st.params, st.opt, srv), jnp.int32(w), wire)
        else:
            self.state, g_dir = r._step(self.state, jnp.int32(w), gflat,
                                        jnp.int32(view.tau))
        # device-side EMA; the queue keeps the host <= depth steps ahead
        # (g_dir comes out of the arrival step, so waiting on it bounds
        # the whole grad+commit+apply chain of that arrival)
        loss = jnp.asarray(loss, jnp.float32)
        rn = self.running
        self.running = (loss if rn is None
                        else self.ema * rn + (1 - self.ema) * loss)
        self.queue.push((self.running, g_dir))
        it_after = view.iters + 1
        if it_after % self.record_every == 0:
            self.times.append(view.t)
            self.iters.append(it_after)
            if self.eval_fn is not None:
                self.losses.append(float(self.eval_fn(
                    r.engine.spec.unravel(self.state.params))))
            else:
                self.losses.append(float(self.running))
            # norm of the RAW arriving gradient — what SimResult records
            # (the folded direction g_dir only gates the device queue)
            self.gnorms.append(float(jnp.sqrt(jnp.sum(jnp.square(gflat)))))
        return True  # every async rule applies every arrival

    def on_arrival(self, view) -> bool:
        loss, gflat = self.grad_for(view)
        return self.commit(view, loss, gflat)

    # --------------------------------------------------------------- result

    def result(self, stats: LoopStats, **extra) -> AsyncResult:
        return AsyncResult(
            name=self.r.algo.name,
            times=np.asarray(self.times), iters=np.asarray(self.iters),
            losses=np.asarray(self.losses), gnorms=np.asarray(self.gnorms),
            state=self.state, tau_max=stats.tau_max,
            n_grads=self.n_grads, stats=stats,
            wire_rows=self.wire_rows, wire_bytes=self.wire_bytes,
            payload_bytes=self.payload_bytes,
            snap_encodes=self.snap_encodes, snap_reuses=self.snap_reuses,
            digests=None if self.digests is None else tuple(self.digests),
            **extra,
        )


class AsyncRunner:
    """Event-driven per-arrival training session over the flat engine.

    ``engine`` fixes the flat layout (and the mesh, when P-axis sharded);
    ``algo`` is an ``AsyncAlgo`` or a name from ``core.algos.ASYNC_ALGOS``;
    ``opt`` any optimizer with a flat twin.  ``grad_fn(params, batch, key)
    -> (loss, grads)`` computes one worker's stochastic gradient on the
    (stale) pytree params — the same callable contract as ``simulate`` —
    and is jitted once, so a runner and a simulator sharing one ``grad_fn``
    execute the identical compiled gradient.
    """

    def __init__(self, engine: DuDeEngine, algo, opt,
                 grad_fn: Callable[..., tuple], *,
                 queue_depth: int = 2,
                 max_in_flight: Optional[int] = None):
        self.engine = engine
        self.algo: AsyncAlgo = (make_async_algo(algo, engine)
                                if isinstance(algo, str) else algo)
        self.fopt = flat_twin(opt)
        self.max_in_flight = max_in_flight
        self.queue_depth = queue_depth
        spec = engine.spec
        self._grad = jax.jit(grad_fn)
        self._unravel = jax.jit(spec.unravel)
        ravel_kw = {}
        if engine.mesh is not None:
            # land the raveled gradient straight in the engine's segment-
            # range P-axis layout, so commit's shard_map sees local shards
            from ..sharding import flat_vec_sharding
            ravel_kw["out_shardings"] = flat_vec_sharding(
                spec, engine.mesh, engine.paxes)
        self._ravel = jax.jit(lambda g: spec.ravel(g, jnp.float32),
                              **ravel_kw)
        # NOT donated: the freshest worker snapshot aliases state.params
        self._step = jax.jit(self._arrival_step)
        # Compressed commit formats also delta-encode the n worker model
        # snapshots against a fixed master base (run() start) instead of
        # keeping n full [P] f32 copies: snapshot w is stored as the tiled
        # int8 encoding of (master - base), reconstructed lazily at gradient
        # time.  Physical-staleness memory drops from 4nP to
        # ~nP(1 + 4/128) + 4P bytes.  The f32 format keeps the exact
        # aliasing path (trace replays stay bit-for-bit).
        codec = engine.codec
        self._compressed = codec.compressed
        # Sparse commit transport: when the engine carries touched-tile
        # metadata and the algo is the plain DuDe commit, the arrival step
        # splits into the sender encode (dense math, produces the O(k * cap)
        # SparseRow and advances EF) and the receiver fold (scatter-decode
        # straight into the slab) — the state crossing between them is the
        # wire row, whose bytes the run counts (AsyncResult.wire_bytes /
        # payload_bytes).
        self._sparse = engine.sparse_meta and self.algo.name == "dude"
        if self._sparse:
            from ..core.compression import sparse_wire_nbytes
            self._wire_nbytes = sparse_wire_nbytes
            self._encode = jax.jit(engine.encode_sparse_commit)

            def _fold_step(state, worker, row):
                srv, g = engine.sparse_fold(state.engine, worker, row)
                t_new = state.opt.step + 1
                pf, slots = self.fopt.update(state.params, g,
                                             state.opt.slots, t_new)
                return FlatTrainState(pf, FlatOptState(t_new, slots), srv), g

            self._step_sparse = jax.jit(_fold_step)
        if self._compressed:
            if self._sparse:
                # snapshots ride the same wire format (full tile capacity —
                # a whole-model delta touches most tiles); decode-identical
                # to the dense (q, scale) snapshot pair
                from ..core.compression import sparse_decode
                P = engine.P
                self._snap_encode = jax.jit(
                    lambda params, base: codec.encode_sparse(
                        params.astype(jnp.float32) - base))
                self._snap_unravel = jax.jit(
                    lambda base, row: spec.unravel(
                        base + sparse_decode(row, P)))
            else:
                self._snap_encode = jax.jit(
                    lambda params, base: codec.encode(
                        params.astype(jnp.float32) - base))
                self._snap_unravel = jax.jit(
                    lambda base, q, s: spec.unravel(
                        base + codec.decode(q, s)))

    def _arrival_step(self, state: FlatTrainState, worker, grad, tau):
        """One server iteration: algo rule (commit for DuDe, s(τ)-damped
        commit for the staleness family) + flat apply, all elementwise on
        the (possibly P-sharded) slabs."""
        srv, g = self.algo.arrival(state.engine, worker, grad, tau)
        t_new = state.opt.step + 1
        pf, slots = self.fopt.update(state.params, g, state.opt.slots, t_new)
        return FlatTrainState(pf, FlatOptState(t_new, slots), srv), g

    # ------------------------------------------------------------- state

    def init_state(self, params: Pytree) -> FlatTrainState:
        """Fresh ``FlatTrainState`` (same construction as the Trainer's)."""
        from ..launch.steps import init_flat_train_state
        return init_flat_train_state(self.engine, self.fopt, params,
                                     algo=self.algo)

    def session(self, state: FlatTrainState,
                sample_fn: Optional[Callable] = None, *, seed: int = 0,
                record_every: int = 10, eval_fn: Optional[Callable] = None,
                ema: float = 0.9, key_mode: str = "arrival",
                record_digests: bool = False) -> _RunSession:
        """The per-arrival math session ``run`` drives — exposed so the
        multi-host ``HostRunner`` can drive the identical path from socket
        readiness (``sample_fn`` may be None when gradients arrive remotely
        and ``grad_for`` is never called)."""
        return _RunSession(self, state, sample_fn, seed=seed,
                           record_every=record_every, eval_fn=eval_fn,
                           ema=ema, key_mode=key_mode,
                           record_digests=record_digests)

    # --------------------------------------------------------------- run

    def run(
        self,
        process: ArrivalProcess,
        total_iters: int,
        sample_fn: Callable,
        state: FlatTrainState,
        *,
        seed: int = 0,
        record_every: int = 10,
        eval_fn: Optional[Callable] = None,
        ema: float = 0.9,
        max_time: Optional[float] = None,
        key_mode: str = "arrival",
        record_digests: bool = False,
    ) -> AsyncResult:
        """Drive ``total_iters`` per-arrival server iterations.

        ``sample_fn(worker, rng) -> batch`` draws from that worker's local
        data; ``seed`` feeds both the host rng (sampling + routing draws)
        and the gradient PRNG key — pass the seed a ``simulate`` run used
        and a trace replay reproduces its parameters bit-for-bit.  With
        ``key_mode="worker"`` the keys and sampling streams are
        dispatch-deterministic per worker (the multi-host convention — use
        it to replay a ``HostRunner`` trace); ``record_digests`` stamps
        every arrival's gradient (``AsyncResult.digests``) for comparison
        against a recorded multi-host run.
        """
        n = self.engine.n_workers
        if process.n != n:
            raise ValueError(
                f"process has n={process.n}, engine n_workers={n}")
        sess = self.session(state, sample_fn, seed=seed,
                            record_every=record_every, eval_fn=eval_fn,
                            ema=ema, key_mode=key_mode,
                            record_digests=record_digests)
        try:
            stats = drive_arrivals(
                process, total_iters, sess.on_arrival, sess.deliver,
                route=self.algo.route, rng=sess.rng,
                max_in_flight=self.max_in_flight, max_time=max_time)
        finally:
            # a crashed arrival callback must not leave in-flight device
            # values dangling — flush the queue on every exit path
            sess.queue.flush()
        return sess.result(stats)

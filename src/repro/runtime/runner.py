"""AsyncRunner: per-arrival training on the flat engine state.

The production counterpart of the event-driven simulator: the same arrival
semantics (``runtime/loop.py``) driving the paper's fully-asynchronous
server iteration on the canonical ``FlatTrainState`` — per arrival, one
``DuDeEngine.commit`` (or an ``AsyncAlgo`` rule from ``core/algos.py``) plus
the flat optimizer apply, compiled as ONE jitted device step that is
elementwise on the P-axis-sharded ``[P]`` slabs (mesh-native engines commit
under their ``shard_map``, so a sharded arrival step moves zero bytes).

Differences from the simulator, by design:

* math runs on flat slabs (identical values: flat and pytree applies agree
  bit-for-bit on f32 params, so a runner replaying a simulator trace
  reproduces its parameters exactly — ``tests/test_runtime.py``);
* the host never blocks per arrival: device steps are pushed through a
  bounded ``DeviceQueue`` (depth 2 = double buffering) that only waits when
  the device is ``queue_depth`` full steps behind the scheduler, and the
  loss EMA stays on device between record points;
* worker model snapshots are flat ``[P]`` vectors (n of them — the price of
  physical staleness), handed out by the loop's ``deliver`` hook.  The
  arrival step therefore does NOT donate its state: the freshest snapshot
  aliases ``state.params``.  Under a compressed ``commit_format`` the n
  snapshots are delta-encoded (tiled int8, ``core/compression.py``) against
  the run-start master instead of stored as full copies — ~3.9x less
  snapshot memory; commits themselves are compressed inside
  ``DuDeEngine.commit`` (int8 payload + per-tile scales + EF residual).

Documented in docs/async.md ("The AsyncRunner" / "In-flight depth and the
device queue").
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.algos import AsyncAlgo, make_async_algo
from ..core.engine import DuDeEngine
from ..optim import FlatOptState, FlatTrainState, flat_twin
from .arrivals import ArrivalProcess, ArrivalTrace
from .loop import LoopStats, drive_arrivals

Pytree = Any

__all__ = ["AsyncResult", "DeviceQueue", "AsyncRunner"]


class DeviceQueue:
    """Bounded queue of in-flight device computations.

    ``push(x)`` enqueues a device value the host does not need yet; once
    more than ``depth`` values are outstanding the oldest is waited on —
    so the host runs at most ``depth`` steps ahead of the device (depth 2 =
    classic double buffering: one step executing, one queued behind it)
    while never synchronizing when a buffer slot is free.
    """

    def __init__(self, depth: int = 2):
        if depth < 1:
            raise ValueError(f"queue depth {depth} must be >= 1")
        self.depth = depth
        self._q: collections.deque = collections.deque()
        self.waits = 0  # times the host actually blocked (for tests/bench)

    def push(self, value) -> None:
        self._q.append(value)
        if len(self._q) > self.depth:
            self.waits += 1
            jax.block_until_ready(self._q.popleft())

    def flush(self) -> None:
        while self._q:
            jax.block_until_ready(self._q.popleft())

    def __len__(self) -> int:
        return len(self._q)


@dataclasses.dataclass
class AsyncResult:
    """One AsyncRunner run, mirror of the simulator's ``SimResult`` plus the
    loop's scheduling stats and the recorded trace."""

    name: str
    times: np.ndarray        # simulated clock at each record point
    iters: np.ndarray        # server iterations at each record point
    losses: np.ndarray       # running train-loss EMA (or eval_fn) at records
    gnorms: np.ndarray       # |g| at each record point
    state: FlatTrainState    # final train state (flat)
    tau_max: int
    n_grads: int             # stochastic gradients computed
    stats: LoopStats
    # sparse commit transport (engines with sparse_meta): SparseRow commits
    # shipped host->device and their actual wire bytes (0 on dense runs)
    wire_rows: int = 0
    wire_bytes: int = 0
    # snapshot-encode cache: encodes actually run vs deliveries served from
    # the cache because params were unchanged since the last delivery
    snap_encodes: int = 0
    snap_reuses: int = 0

    @property
    def trace(self) -> ArrivalTrace:
        return self.stats.trace


class AsyncRunner:
    """Event-driven per-arrival training session over the flat engine.

    ``engine`` fixes the flat layout (and the mesh, when P-axis sharded);
    ``algo`` is an ``AsyncAlgo`` or a name from ``core.algos.ASYNC_ALGOS``;
    ``opt`` any optimizer with a flat twin.  ``grad_fn(params, batch, key)
    -> (loss, grads)`` computes one worker's stochastic gradient on the
    (stale) pytree params — the same callable contract as ``simulate`` —
    and is jitted once, so a runner and a simulator sharing one ``grad_fn``
    execute the identical compiled gradient.
    """

    def __init__(self, engine: DuDeEngine, algo, opt,
                 grad_fn: Callable[..., tuple], *,
                 queue_depth: int = 2,
                 max_in_flight: Optional[int] = None):
        self.engine = engine
        self.algo: AsyncAlgo = (make_async_algo(algo, engine)
                                if isinstance(algo, str) else algo)
        self.fopt = flat_twin(opt)
        self.max_in_flight = max_in_flight
        self.queue_depth = queue_depth
        spec = engine.spec
        self._grad = jax.jit(grad_fn)
        self._unravel = jax.jit(spec.unravel)
        ravel_kw = {}
        if engine.mesh is not None:
            # land the raveled gradient straight in the engine's segment-
            # range P-axis layout, so commit's shard_map sees local shards
            from ..sharding import flat_vec_sharding
            ravel_kw["out_shardings"] = flat_vec_sharding(
                spec, engine.mesh, engine.paxes)
        self._ravel = jax.jit(lambda g: spec.ravel(g, jnp.float32),
                              **ravel_kw)
        # NOT donated: the freshest worker snapshot aliases state.params
        self._step = jax.jit(self._arrival_step)
        # Compressed commit formats also delta-encode the n worker model
        # snapshots against a fixed master base (run() start) instead of
        # keeping n full [P] f32 copies: snapshot w is stored as the tiled
        # int8 encoding of (master - base), reconstructed lazily at gradient
        # time.  Physical-staleness memory drops from 4nP to
        # ~nP(1 + 4/128) + 4P bytes.  The f32 format keeps the exact
        # aliasing path (trace replays stay bit-for-bit).
        codec = engine.codec
        self._compressed = codec.compressed
        # Sparse commit transport: when the engine carries touched-tile
        # metadata and the algo is the plain DuDe commit, the arrival step
        # splits into the sender encode (dense math, produces the O(k * cap)
        # SparseRow and advances EF) and the receiver fold (scatter-decode
        # straight into the slab) — the state crossing between them is the
        # wire row, whose bytes the run counts (AsyncResult.wire_bytes).
        self._sparse = engine.sparse_meta and self.algo.name == "dude"
        if self._sparse:
            from ..core.compression import sparse_wire_nbytes
            self._wire_nbytes = sparse_wire_nbytes
            self._encode = jax.jit(engine.encode_sparse_commit)

            def _fold_step(state, worker, row):
                srv, g = engine.sparse_fold(state.engine, worker, row)
                t_new = state.opt.step + 1
                pf, slots = self.fopt.update(state.params, g,
                                             state.opt.slots, t_new)
                return FlatTrainState(pf, FlatOptState(t_new, slots), srv), g

            self._step_sparse = jax.jit(_fold_step)
        if self._compressed:
            if self._sparse:
                # snapshots ride the same wire format (full tile capacity —
                # a whole-model delta touches most tiles); decode-identical
                # to the dense (q, scale) snapshot pair
                from ..core.compression import sparse_decode
                P = engine.P
                self._snap_encode = jax.jit(
                    lambda params, base: codec.encode_sparse(
                        params.astype(jnp.float32) - base))
                self._snap_unravel = jax.jit(
                    lambda base, row: spec.unravel(
                        base + sparse_decode(row, P)))
            else:
                self._snap_encode = jax.jit(
                    lambda params, base: codec.encode(
                        params.astype(jnp.float32) - base))
                self._snap_unravel = jax.jit(
                    lambda base, q, s: spec.unravel(
                        base + codec.decode(q, s)))

    def _arrival_step(self, state: FlatTrainState, worker, grad):
        """One server iteration: algo rule (commit for DuDe) + flat apply,
        all elementwise on the (possibly P-sharded) slabs."""
        srv, g = self.algo.arrival(state.engine, worker, grad)
        t_new = state.opt.step + 1
        pf, slots = self.fopt.update(state.params, g, state.opt.slots, t_new)
        return FlatTrainState(pf, FlatOptState(t_new, slots), srv), g

    # ------------------------------------------------------------- state

    def init_state(self, params: Pytree) -> FlatTrainState:
        """Fresh ``FlatTrainState`` (same construction as the Trainer's)."""
        from ..launch.steps import init_flat_train_state
        return init_flat_train_state(self.engine, self.fopt, params,
                                     algo=self.algo)

    # --------------------------------------------------------------- run

    def run(
        self,
        process: ArrivalProcess,
        total_iters: int,
        sample_fn: Callable,
        state: FlatTrainState,
        *,
        seed: int = 0,
        record_every: int = 10,
        eval_fn: Optional[Callable] = None,
        ema: float = 0.9,
        max_time: Optional[float] = None,
    ) -> AsyncResult:
        """Drive ``total_iters`` per-arrival server iterations.

        ``sample_fn(worker, rng) -> batch`` draws from that worker's local
        data; ``seed`` feeds both the host rng (sampling + routing draws)
        and the gradient PRNG key — pass the seed a ``simulate`` run used
        and a trace replay reproduces its parameters bit-for-bit.
        """
        n = self.engine.n_workers
        if process.n != n:
            raise ValueError(
                f"process has n={process.n}, engine n_workers={n}")
        rng = np.random.default_rng(seed)
        key = jax.random.PRNGKey(seed)
        queue = DeviceQueue(self.queue_depth)

        box = {"state": state, "key": key, "running": None, "n_grads": 0,
               "wire_rows": 0, "wire_bytes": 0,
               "snap_encodes": 0, "snap_reuses": 0}
        # deliver() cache: the params object the last snapshot encode ran
        # on, and its encoding.  Identity (`is`) comparison — the arrival
        # step returns a NEW params array whenever anything committed, so an
        # unchanged object means an unchanged snapshot; a delivery between
        # two commits (or before the first) reuses the last encode instead
        # of re-running it.  The object itself is held (not id()) so a GC'd
        # array can never alias a stale id.
        snap_cache = {"params": None, "enc": None}
        # every worker starts on the initial model (version 0)
        if self._compressed:
            # delta-encoded snapshots against the run-start master; the
            # zero delta (q=0 decodes to exactly 0) is ONE encode delivered
            # n ways — the first n cache reuses
            base = state.params
            zero_delta = self._snap_encode(base, base)
            box["snap_encodes"] = 1
            box["snap_reuses"] = n - 1
            snap_cache.update(params=base, enc=zero_delta)
            worker_snaps = [zero_delta for _ in range(n)]
            worker_params = None
        else:
            worker_params = [state.params for _ in range(n)]
        times, iters, losses, gnorms = [], [], [], []

        def worker_model(w: int) -> Pytree:
            if self._sparse:
                return self._snap_unravel(base, worker_snaps[w])
            if self._compressed:
                q, s = worker_snaps[w]
                return self._snap_unravel(base, q, s)
            return self._unravel(worker_params[w])

        def commit_arrival(worker, gflat):
            if not self._sparse:
                return self._step(box["state"], worker, gflat)
            st = box["state"]
            srv, wire = self._encode(st.engine, worker, gflat)
            box["wire_rows"] += 1
            box["wire_bytes"] += self._wire_nbytes(wire)
            return self._step_sparse(FlatTrainState(st.params, st.opt, srv),
                                     worker, wire)

        def on_arrival(view) -> bool:
            box["key"], k1 = jax.random.split(box["key"])
            batch = sample_fn(view.worker, rng)
            loss, g = self._grad(worker_model(view.worker), batch, k1)
            gflat = self._ravel(g)
            box["n_grads"] += 1
            box["state"], g_dir = commit_arrival(jnp.int32(view.worker),
                                                 gflat)
            # device-side EMA; the queue keeps the host <= depth steps ahead
            # (g_dir comes out of the arrival step, so waiting on it bounds
            # the whole grad+commit+apply chain of that arrival)
            r = box["running"]
            box["running"] = loss if r is None else ema * r + (1 - ema) * loss
            queue.push((box["running"], g_dir))
            it_after = view.iters + 1
            if it_after % record_every == 0:
                times.append(view.t)
                iters.append(it_after)
                if eval_fn is not None:
                    losses.append(float(eval_fn(
                        self.engine.spec.unravel(box["state"].params))))
                else:
                    losses.append(float(box["running"]))
                # norm of the RAW arriving gradient — what SimResult records
                # (the folded direction g_dir only gates the device queue)
                gnorms.append(float(jnp.sqrt(jnp.sum(jnp.square(gflat)))))
            return True  # every async rule applies every arrival

        def deliver(worker: int) -> None:
            if self._compressed:
                params = box["state"].params
                if snap_cache["params"] is not params:
                    snap_cache["params"] = params
                    snap_cache["enc"] = self._snap_encode(params, base)
                    box["snap_encodes"] += 1
                else:
                    box["snap_reuses"] += 1
                worker_snaps[worker] = snap_cache["enc"]
            else:
                worker_params[worker] = box["state"].params

        stats = drive_arrivals(
            process, total_iters, on_arrival, deliver,
            route=self.algo.route, rng=rng,
            max_in_flight=self.max_in_flight, max_time=max_time)
        queue.flush()
        return AsyncResult(
            name=self.algo.name,
            times=np.asarray(times), iters=np.asarray(iters),
            losses=np.asarray(losses), gnorms=np.asarray(gnorms),
            state=box["state"], tau_max=stats.tau_max,
            n_grads=box["n_grads"], stats=stats,
            wire_rows=box["wire_rows"], wire_bytes=box["wire_bytes"],
            snap_encodes=box["snap_encodes"], snap_reuses=box["snap_reuses"],
        )

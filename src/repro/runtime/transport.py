"""Framed wire transport: commit rows and model snapshots between hosts.

The multi-host runtime (``runtime/hostloop.py``) moves exactly two kinds of
tensor payload: per-arrival commits worker -> server and delta-encoded model
snapshots server -> worker.  This module owns the bytes: a length-prefixed
frame format with a msgpack (or JSON-fallback) header and a raw
concatenated-array payload whose codecs reproduce ``core/compression.py``'s
arrays BYTE-FOR-BYTE — a ``SparseRow`` decoded from a frame is bitwise the
``SparseRow`` that was encoded, so the engine's fold math cannot tell a
socket hop from an in-process handoff.

Frame layout (everything big-endian in the fixed prefix)::

    0          2     3     4              8               12
    +----------+-----+-----+--------------+----------------+---------+---------+-----+
    | magic DD | ver | pad | header bytes | payload bytes  | header  | payload | pad |
    +----------+-----+-----+--------------+----------------+---------+---------+-----+

* ``magic`` = ``b"DD"`` (DuDe), ``ver`` = :data:`PROTOCOL_VERSION`; a frame
  with the wrong magic/version fails fast with ``TransportError`` instead of
  desynchronizing the stream.
* the header is a small dict — message kind, worker/job ids, loss, digest,
  and the payload's array manifest (dtype + shape per array) — serialized
  with msgpack when available, JSON otherwise (the container may lack
  msgpack; both ends negotiate nothing: the prefix ``pad`` byte carries the
  header codec id so a JSON peer and a msgpack peer fail loudly, not
  silently).
* the payload is the arrays' raw little-endian bytes, concatenated in
  manifest order, zero-padded so every frame is a multiple of
  :data:`FRAME_ALIGN` bytes (receivers can keep slab-aligned ring buffers).

Transports:

* :class:`SocketTransport` — a stream socket endpoint with per-call
  timeouts, exponential-backoff retry on transient send/recv errors, a
  partial-frame receive buffer (a timeout mid-frame never loses bytes), and
  byte counters (``wire_sent`` / ``wire_recv``).
* :class:`InProcTransport` — the in-process twin: ``InProcTransport.pair()``
  returns two connected endpoints whose queues carry the SAME encoded frame
  bytes, so every protocol path (frame encode, header codec, payload
  manifest, decode) is exercised without opening a socket.  Thread-safe;
  ``close()`` makes the peer's ``recv`` raise ``TransportClosed`` once
  drained — which is how tests simulate a dead worker.

Byte accounting: ``framed_nbytes`` / ``commit_frame_nbytes`` compute the
exact on-wire size of a frame without sending it — the single-process
``AsyncRunner`` uses them so its ``wire_bytes`` counter reports what a
socket WOULD carry (header + count + padding), not just the analytic
payload (``AsyncResult.payload_bytes``).  Documented in docs/async.md
("Multi-host transport").
"""

from __future__ import annotations

import json
import socket
import struct
import threading
import time
from collections import deque
from typing import NamedTuple, Optional, Sequence

import numpy as np

from ..core.compression import SparseRow, commit_digest  # noqa: F401 (re-export)

__all__ = [
    "PROTOCOL_VERSION", "FRAME_ALIGN", "Message",
    "TransportError", "TransportClosed", "TransportTimeout",
    "encode_frame", "decode_frame", "framed_nbytes", "commit_header",
    "commit_frame_nbytes", "pack_arrays", "unpack_arrays",
    "sparse_row_arrays", "sparse_row_from_arrays",
    "SocketTransport", "InProcTransport", "connect", "serve_listener",
]

PROTOCOL_VERSION = 1
FRAME_ALIGN = 8
_MAGIC = b"DD"
_PREFIX = struct.Struct("!2sBBII")  # magic, version, header-codec, hlen, plen

try:
    import msgpack  # type: ignore

    _HEADER_CODEC = 1

    def _dumps(obj) -> bytes:
        return msgpack.packb(obj, use_bin_type=True)

    def _loads(b: bytes):
        return msgpack.unpackb(b, raw=False)
except ImportError:  # pragma: no cover - container without msgpack
    _HEADER_CODEC = 2

    def _dumps(obj) -> bytes:
        return json.dumps(obj, separators=(",", ":"), sort_keys=True).encode()

    def _loads(b: bytes):
        return json.loads(b.decode())


class TransportError(Exception):
    """A frame could not be sent, received, or parsed."""


class TransportClosed(TransportError):
    """The peer closed the connection (EOF) — dead-worker signal."""


class TransportTimeout(TransportError):
    """No complete frame inside the deadline (partial bytes are kept)."""


class Message(NamedTuple):
    """One decoded frame: a kind, its header metadata, and payload arrays."""

    kind: str
    meta: dict
    arrays: tuple  # numpy arrays, in manifest order


# ------------------------------------------------------------ array payloads

def _wire_dtype(dt: np.dtype) -> str:
    """Canonical little-endian dtype tag (``<f4``, ``<i4``, ``|i1``...)."""
    return np.dtype(dt).newbyteorder("<").str


def pack_arrays(arrays: Sequence[np.ndarray]) -> tuple[list, bytes]:
    """Arrays -> (manifest, payload bytes).

    The manifest is ``[[dtype_str, [shape...]], ...]``; the payload is the
    arrays' little-endian C-order bytes concatenated in manifest order —
    for a ``SparseRow`` that is exactly ``cap*(2k+8) + 4`` bytes, the
    analytic ``sparse_wire_nbytes``.
    """
    manifest, chunks = [], []
    for x in arrays:
        a = np.asarray(x)
        a = a.astype(a.dtype.newbyteorder("<"), copy=False)
        # manifest BEFORE any contiguity fixup: ascontiguousarray promotes
        # 0-d arrays to [1] and would corrupt scalar shapes (SparseRow.count)
        manifest.append([_wire_dtype(a.dtype), list(a.shape)])
        chunks.append(a.tobytes())  # tobytes is C-order regardless of layout
    return manifest, b"".join(chunks)


def unpack_arrays(manifest: Sequence, payload: bytes) -> tuple:
    """Inverse of :func:`pack_arrays` — bitwise, dtype- and shape-exact."""
    out, off = [], 0
    for dt_str, shape in manifest:
        dt = np.dtype(dt_str)
        n = int(np.prod(shape, dtype=np.int64)) if shape else 1
        nb = n * dt.itemsize
        if off + nb > len(payload):
            raise TransportError(
                f"payload truncated: manifest wants {nb} bytes at offset "
                f"{off}, frame carries {len(payload)}")
        a = np.frombuffer(payload, dt, count=n, offset=off)
        out.append(a.reshape(tuple(shape)))
        off += nb
    return tuple(out)


def sparse_row_arrays(row: SparseRow) -> tuple:
    """``SparseRow`` -> its 5 wire arrays in field order (host numpy)."""
    return tuple(np.asarray(x) for x in row)


def sparse_row_from_arrays(arrays: Sequence[np.ndarray]) -> SparseRow:
    """5 wire arrays -> ``SparseRow`` (numpy leaves; jnp lifts on use)."""
    if len(arrays) != len(SparseRow._fields):
        raise TransportError(
            f"SparseRow payload has {len(arrays)} arrays, "
            f"wants {len(SparseRow._fields)}")
    return SparseRow(*arrays)


# ------------------------------------------------------------------- framing

def encode_frame(kind: str, meta: Optional[dict] = None,
                 arrays: Sequence[np.ndarray] = ()) -> bytes:
    """One complete wire frame for ``Message(kind, meta, arrays)``."""
    header = dict(meta or {})
    header["k"] = kind
    manifest, payload = pack_arrays(arrays)
    if manifest:
        header["a"] = manifest
    hb = _dumps(header)
    body_len = _PREFIX.size + len(hb) + len(payload)
    pad = (-body_len) % FRAME_ALIGN
    return b"".join([
        _PREFIX.pack(_MAGIC, PROTOCOL_VERSION, _HEADER_CODEC,
                     len(hb), len(payload)),
        hb, payload, b"\x00" * pad,
    ])


def framed_nbytes(kind: str, meta: Optional[dict] = None,
                  arrays_nbytes: int = 0,
                  manifest: Optional[list] = None) -> int:
    """Exact on-wire size of a frame WITHOUT materializing its payload.

    ``manifest`` is the ``pack_arrays`` manifest the header would carry
    (pass it when the frame has arrays); ``arrays_nbytes`` their summed raw
    bytes.  This is how the single-process runner accounts framed bytes
    per commit with no device sync — the header is actually serialized, so
    varint-width effects of worker/seq ids are captured exactly.
    """
    header = dict(meta or {})
    header["k"] = kind
    if manifest:
        header["a"] = manifest
    body_len = _PREFIX.size + len(_dumps(header)) + arrays_nbytes
    return body_len + (-body_len) % FRAME_ALIGN


def commit_header(worker: int, job: int, loss: float = 0.0,
                  digest: str = "0" * 8) -> dict:
    """The canonical COMMIT header — ONE constructor for both the hosted
    sender (real loss/digest) and the simulated runner's byte accountant
    (placeholders; msgpack float64 and the 8-hex digest are fixed-width, so
    placeholder and real headers are the same size for the same ids)."""
    return {"w": int(worker), "j": int(job), "loss": float(loss),
            "dg": digest}


def commit_frame_nbytes(worker: int, job: int, manifest: list,
                        payload_nbytes: int) -> int:
    """On-wire bytes of one COMMIT frame carrying ``payload_nbytes`` of
    array payload described by ``manifest``."""
    return framed_nbytes("commit", commit_header(worker, job),
                         payload_nbytes, manifest)


def decode_frame(buf: bytes) -> tuple[Message, int]:
    """Decode one frame from the head of ``buf`` -> (message, bytes used).

    Raises ``TransportTimeout`` when ``buf`` holds only a partial frame
    (the caller keeps the bytes and retries) and ``TransportError`` on a
    corrupt prefix.
    """
    if len(buf) < _PREFIX.size:
        raise TransportTimeout("partial frame prefix")
    magic, ver, codec, hlen, plen = _PREFIX.unpack_from(buf)
    if magic != _MAGIC:
        raise TransportError(f"bad frame magic {magic!r} (stream desync?)")
    if ver != PROTOCOL_VERSION:
        raise TransportError(
            f"peer speaks protocol v{ver}, this end v{PROTOCOL_VERSION}")
    if codec != _HEADER_CODEC:
        raise TransportError(
            f"peer frames headers with codec {codec}, this end "
            f"{_HEADER_CODEC} (msgpack vs JSON fallback mismatch)")
    body_len = _PREFIX.size + hlen + plen
    total = body_len + (-body_len) % FRAME_ALIGN
    if len(buf) < total:
        raise TransportTimeout("partial frame body")
    payload = bytes(buf[_PREFIX.size + hlen:body_len])
    try:
        header = _loads(bytes(buf[_PREFIX.size:_PREFIX.size + hlen]))
        kind = header.pop("k")
        manifest = header.pop("a", [])
    except Exception as e:
        # corrupt header bytes surface as msgpack/JSON/KeyError internals;
        # wrap them so every malformed frame fails with the structured
        # protocol error (fuzzed by tests/test_transport.py)
        raise TransportError(f"corrupt frame header: {e!r}") from None
    try:
        arrays = unpack_arrays(manifest, payload) if manifest else ()
    except TransportError:
        raise
    except Exception as e:
        raise TransportError(f"corrupt payload manifest: {e!r}") from None
    return Message(kind, header, arrays), total


# ---------------------------------------------------------------- transports

class _BaseTransport:
    """send/recv byte counters + the framed-message API both twins share.

    ``send`` is serialized by a lock so a heartbeat thread (``run_worker``
    pings while the main thread sits in a long gradient compute) can never
    interleave its frame bytes with a commit's mid-stream.
    """

    def __init__(self):
        self.wire_sent = 0
        self.wire_recv = 0
        self._send_lock = threading.Lock()

    def send(self, kind: str, meta: Optional[dict] = None,
             arrays: Sequence[np.ndarray] = ()) -> int:
        frame = encode_frame(kind, meta, arrays)
        with self._send_lock:
            self._send_bytes(frame)
            self.wire_sent += len(frame)
        return len(frame)

    def recv(self, timeout: Optional[float] = None) -> Message:
        raise NotImplementedError

    def close(self) -> None:
        raise NotImplementedError

    def _send_bytes(self, frame: bytes) -> None:
        raise NotImplementedError


class SocketTransport(_BaseTransport):
    """One framed endpoint over a stream socket.

    ``timeout`` bounds each send/recv call; transient failures (EAGAIN /
    socket timeouts on send) retry with exponential backoff — ``retries``
    attempts spaced ``backoff_s * 2**k`` — before raising
    ``TransportTimeout``.  EOF raises ``TransportClosed`` (the heartbeat
    loop's dead-worker signal).  A recv deadline that lands mid-frame keeps
    the partial bytes buffered, so the next call resumes the same frame.
    """

    def __init__(self, sock: socket.socket, *, timeout: float = 30.0,
                 retries: int = 5, backoff_s: float = 0.05):
        super().__init__()
        self.sock = sock
        self.timeout = timeout
        self.retries = retries
        self.backoff_s = backoff_s
        self._buf = bytearray()
        sock.setblocking(True)
        try:
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:
            pass  # AF_UNIX socketpairs have no TCP layer

    def fileno(self) -> int:
        return self.sock.fileno()

    def _send_bytes(self, frame: bytes) -> None:
        view, attempt = memoryview(frame), 0
        while view:
            try:
                self.sock.settimeout(self.timeout)
                sent = self.sock.send(view)
                if sent == 0:
                    raise TransportClosed("peer closed during send")
                view = view[sent:]
                attempt = 0
            except (socket.timeout, BlockingIOError, InterruptedError):
                if attempt >= self.retries:
                    raise TransportTimeout(
                        f"send stalled after {self.retries} retries") from None
                time.sleep(self.backoff_s * (2 ** attempt))
                attempt += 1
            except OSError as e:
                raise TransportClosed(f"send failed: {e}") from None

    def recv(self, timeout: Optional[float] = None) -> Message:
        deadline = time.monotonic() + (self.timeout if timeout is None
                                       else timeout)
        while True:
            try:
                msg, used = decode_frame(self._buf)
                del self._buf[:used]
                self.wire_recv += used
                return msg
            except TransportTimeout:
                pass  # need more bytes
            remain = deadline - time.monotonic()
            if remain <= 0:
                raise TransportTimeout(
                    f"no complete frame in {timeout if timeout is not None else self.timeout:.3f}s "
                    f"({len(self._buf)} partial bytes held)")
            try:
                self.sock.settimeout(remain)
                chunk = self.sock.recv(1 << 16)
            except socket.timeout:
                continue
            except (BlockingIOError, InterruptedError):
                continue
            except OSError as e:
                raise TransportClosed(f"recv failed: {e}") from None
            if not chunk:
                raise TransportClosed("peer closed (EOF)")
            self._buf.extend(chunk)

    def close(self) -> None:
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self.sock.close()


class InProcTransport(_BaseTransport):
    """The socketless twin: a connected pair sharing byte queues.

    Frames cross as the SAME encoded bytes a socket would carry — the
    protocol (prefix, header codec, manifests, padding) is exercised end to
    end, only the OS stream is replaced by a deque + condition variable.
    Thread-safe: hostloop tests run worker clients in threads against one
    server loop.  ``close()`` wakes the peer; its ``recv`` raises
    ``TransportClosed`` once the queue drains (dead-worker simulation
    without killing anything).
    """

    def __init__(self):
        super().__init__()
        self._peer: Optional[InProcTransport] = None
        self._q: deque = deque()
        self._cond = threading.Condition()
        self._closed = False

    @classmethod
    def pair(cls) -> tuple["InProcTransport", "InProcTransport"]:
        a, b = cls(), cls()
        a._peer, b._peer = b, a
        return a, b

    def _send_bytes(self, frame: bytes) -> None:
        peer = self._peer
        if peer is None:
            raise TransportError("unpaired InProcTransport")
        with peer._cond:
            if peer._closed or self._closed:
                raise TransportClosed("peer closed")
            peer._q.append(frame)
            peer._cond.notify_all()

    def recv(self, timeout: Optional[float] = None) -> Message:
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while not self._q:
                if self._closed:
                    raise TransportClosed("transport closed (EOF)")
                remain = (None if deadline is None
                          else deadline - time.monotonic())
                if remain is not None and remain <= 0:
                    raise TransportTimeout(f"no frame in {timeout:.3f}s")
                self._cond.wait(remain)
            frame = self._q.popleft()
        msg, used = decode_frame(frame)
        if used != len(frame):
            raise TransportError("queued frame with trailing garbage")
        self.wire_recv += used
        return msg

    def close(self) -> None:
        for end in (self, self._peer):
            if end is None:
                continue
            with end._cond:
                end._closed = True
                end._cond.notify_all()


# ------------------------------------------------------------ socket helpers

def connect(host: str, port: int, *, timeout: float = 30.0, retries: int = 8,
            backoff_s: float = 0.1) -> SocketTransport:
    """Dial the server with exponential backoff (workers may start before
    the server's listener is up — the CI smoke launches them in parallel)."""
    last: Optional[Exception] = None
    for attempt in range(retries + 1):
        try:
            sock = socket.create_connection((host, port), timeout=timeout)
            return SocketTransport(sock, timeout=timeout,
                                   backoff_s=backoff_s)
        except OSError as e:
            last = e
            if attempt < retries:
                time.sleep(backoff_s * (2 ** attempt))
    raise TransportError(f"connect to {host}:{port} failed: {last}")


def serve_listener(host: str, port: int, backlog: int = 16) -> socket.socket:
    """A listening TCP socket (non-blocking accepts; the hostloop polls)."""
    srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    srv.bind((host, port))
    srv.listen(backlog)
    srv.setblocking(False)
    return srv

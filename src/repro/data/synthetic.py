"""Synthetic datasets.

* ``class_gaussian_images`` — CIFAR-like 32x32x3, 10 classes, class-conditional
  Gaussians (CIFAR-10 itself is not available offline; Dirichlet label skew —
  the quantity the paper varies — is preserved exactly).
* ``token_stream`` — per-worker heterogeneous LM token data: each worker draws
  from a distinct Zipf-ish unigram distribution mixed with shared bigram
  structure, so local objectives F_i genuinely differ.
"""

from __future__ import annotations

import numpy as np

__all__ = ["class_gaussian_images", "make_token_sampler"]


def class_gaussian_images(
    n: int = 10000, n_classes: int = 10, hw: int = 32, ch: int = 3, seed: int = 0
):
    """Returns (images [n,hw,hw,ch] f32, labels [n] int64)."""
    rng = np.random.default_rng(seed)
    means = rng.normal(0, 1.0, size=(n_classes, 8))  # low-dim class codes
    proj = rng.normal(0, 1.0, size=(8, hw * hw * ch)) / np.sqrt(8)
    labels = rng.integers(0, n_classes, size=n)
    base = means[labels] @ proj
    x = base + rng.normal(0, 1.0, size=(n, hw * hw * ch))
    x = x.reshape(n, hw, hw, ch).astype(np.float32)
    x = (x - x.mean()) / (x.std() + 1e-8)
    return x, labels.astype(np.int64)


def make_token_sampler(
    n_workers: int, vocab: int, seq_len: int, batch: int,
    heterogeneity: float = 1.0, seed: int = 0,
):
    """Per-worker LM batch sampler with tunable distribution skew.

    Each worker i has unigram logits = shared + heterogeneity * private_i.
    Returns ``sample(worker, rng) -> {"tokens": [B,S], "labels": [B,S]}``.
    """
    rng0 = np.random.default_rng(seed)
    shared = rng0.normal(0, 1, size=vocab)
    private = rng0.normal(0, 1, size=(n_workers, vocab))

    probs = []
    for i in range(n_workers):
        logit = shared + heterogeneity * private[i]
        p = np.exp(logit - logit.max())
        probs.append(p / p.sum())

    def sample(worker: int, rng: np.random.Generator):
        toks = rng.choice(vocab, size=(batch, seq_len + 1), p=probs[worker])
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }

    return sample

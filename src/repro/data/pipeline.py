"""Host-side data pipeline: per-worker shard iterators over a partitioned
dataset, with deterministic shuffling and minibatch assembly.

The event-driven simulator asks for one minibatch per gradient job
(``sample_fn(worker, rng)``); the SPMD production path asks for a *global*
round batch laid out [n_workers, per_worker_batch, ...].
"""

from __future__ import annotations

from typing import Callable

import numpy as np

__all__ = ["ShardIterator", "make_sample_fn", "round_batch_fn"]


class ShardIterator:
    """Infinite shuffled iterator over one worker's index shard."""

    def __init__(self, indices: np.ndarray, batch: int, seed: int = 0):
        self.indices = np.asarray(indices)
        self.batch = batch
        self.rng = np.random.default_rng(seed)
        self._order = self.rng.permutation(len(self.indices))
        self._pos = 0

    def next_indices(self) -> np.ndarray:
        out = []
        need = self.batch
        while need > 0:
            take = min(need, len(self._order) - self._pos)
            out.append(self._order[self._pos : self._pos + take])
            self._pos += take
            need -= take
            if self._pos >= len(self._order):
                self._order = self.rng.permutation(len(self.indices))
                self._pos = 0
        return self.indices[np.concatenate(out)]


def make_sample_fn(
    data: np.ndarray, labels: np.ndarray, shards: list[np.ndarray],
    batch: int, seed: int = 0,
) -> Callable:
    """sample_fn(worker, rng) -> {"x": [B,...], "y": [B]} for the simulator."""
    iters = [ShardIterator(s, batch, seed + i) for i, s in enumerate(shards)]

    def sample(worker: int, rng: np.random.Generator):
        idx = iters[worker].next_indices()
        return {"x": data[idx], "y": labels[idx]}

    return sample


def round_batch_fn(sample_fn: Callable, n_workers: int) -> Callable:
    """Assemble a per-round global batch [n_workers, B, ...] for mode B."""

    def global_batch(rng: np.random.Generator):
        per = [sample_fn(i, rng) for i in range(n_workers)]
        return {
            k: np.stack([p[k] for p in per], axis=0) for k in per[0]
        }

    return global_batch

from .partition import dirichlet_partition, label_distribution
from .pipeline import ShardIterator, make_sample_fn, round_batch_fn
from .synthetic import class_gaussian_images, make_token_sampler

__all__ = [
    "dirichlet_partition", "label_distribution",
    "ShardIterator", "make_sample_fn", "round_batch_fn",
    "class_gaussian_images", "make_token_sampler",
]

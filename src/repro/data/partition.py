"""Dirichlet non-IID data partitioning (paper Appendix C).

For each class k we draw p_k ~ Dir_n(alpha) and assign each instance of class
k to worker i with probability p_{k,i}.  Lower alpha => more heterogeneity.
"""

from __future__ import annotations

import numpy as np

__all__ = ["dirichlet_partition", "label_distribution"]


def dirichlet_partition(
    labels: np.ndarray, n_workers: int, alpha: float, seed: int = 0,
    min_per_worker: int = 1,
) -> list[np.ndarray]:
    """Returns a list of index arrays, one per worker."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    shards: list[list[int]] = [[] for _ in range(n_workers)]
    for k in classes:
        idx = np.nonzero(labels == k)[0]
        rng.shuffle(idx)
        p = rng.dirichlet(np.full(n_workers, alpha))
        assign = rng.choice(n_workers, size=len(idx), p=p)
        for i in range(n_workers):
            shards[i].extend(idx[assign == i].tolist())
    # guarantee every worker has at least min_per_worker samples
    for i in range(n_workers):
        while len(shards[i]) < min_per_worker:
            donor = int(np.argmax([len(s) for s in shards]))
            shards[i].append(shards[donor].pop())
    return [np.asarray(sorted(s), dtype=np.int64) for s in shards]


def label_distribution(labels: np.ndarray, shards: list[np.ndarray]) -> np.ndarray:
    """[n_workers, n_classes] empirical label histogram (heterogeneity probe)."""
    classes = np.unique(labels)
    out = np.zeros((len(shards), len(classes)))
    for i, s in enumerate(shards):
        for j, k in enumerate(classes):
            out[i, j] = np.sum(labels[s] == k)
    return out / np.maximum(out.sum(axis=1, keepdims=True), 1)
